"""Overlap-schedule exactness + collective-count checks on 4 fake devices
(subprocess target; see tests/test_spmd.py).

Acceptance scenario for the overlapped halo schedule (DESIGN.md §5):
``schedule="overlap"`` loss/grads must match the untiled reference to the
same tolerance as ``"sync"`` on a 2x2 mesh for both the ``xla`` and
``pallas`` backends and every grouping granularity; the packed exchange
must drop the halo collective count per group input from 4 to 2 (asserted
by counting ``ppermute`` eqns in the jaxpr); and the no-interior fallback
(tile thinner than the kernel's halo reach) must stay exact.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import (
    build_stack_plan,
    make_tiled_forward,
    make_tiled_loss,
    reference_forward,
    reference_loss,
)
from repro.core.spatial import LayerDef, init_stack_params
from repro.core.tiling import single_group, uniform_grouping
from repro.models.yolo import l2_loss_local

mesh = jax.make_mesh((2, 2), ("th", "tw"))

# conv+pool+BN prefix: exercises fused acts, the BN psum tail, pool-in-group
LAYERS = [
    LayerDef(3, 1, 3, 8, act="leaky"),
    LayerDef(2, 2, 8, 8, pool=True, act="linear"),
    LayerDef(3, 1, 8, 16, act="leaky", batch_norm=True, use_bias=False),
    LayerDef(3, 1, 16, 8, act="leaky"),
]
HW = (32, 32)


def count_ppermutes(closed) -> int:
    """ppermute eqns anywhere in a (closed) jaxpr, sub-jaxprs included."""
    n = 0

    def walk(jaxpr):
        nonlocal n
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "ppermute":
                n += 1
            for v in eqn.params.values():
                for item in v if isinstance(v, (tuple, list)) else (v,):
                    if isinstance(item, jax.core.ClosedJaxpr):
                        walk(item.jaxpr)
                    elif isinstance(item, jax.core.Jaxpr):
                        walk(item)

    walk(closed.jaxpr)
    return n


def max_leaf_err(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def check_exactness():
    params = init_stack_params(jax.random.PRNGKey(0), LAYERS)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *HW, 3))
    for backend in ("xla", "pallas"):
        for groups in (None, uniform_grouping(len(LAYERS), 2), single_group(len(LAYERS))):
            plan = build_stack_plan(
                HW, LAYERS, 2, 2, groups, backend=backend, schedule="overlap"
            )
            y = jax.jit(make_tiled_forward(plan, mesh))(params, x)
            ref = reference_forward(params, x, plan)
            ferr = float(jnp.max(jnp.abs(y - ref)))
            t = 0.1 * jax.random.normal(jax.random.PRNGKey(2), ref.shape)
            loss = jax.jit(make_tiled_loss(plan, mesh, l2_loss_local))
            lerr = abs(float(loss(params, x, t)) - float(
                reference_loss(params, x, t, plan, l2_loss_local)))
            g = jax.jit(jax.grad(lambda p: loss(p, x, t)))(params)
            gr = jax.grad(lambda p: reference_loss(p, x, t, plan, l2_loss_local))(params)
            gerr = max_leaf_err(g, gr)
            ng = len(plan.groups)
            print(f"[{backend} overlap groups={ng}] fwd={ferr:.2e} loss={lerr:.2e} grad={gerr:.2e}")
            assert ferr < 1e-4 and lerr < 1e-5 and gerr < 1e-4
    print("overlap exactness ok")


def check_collective_count():
    params = init_stack_params(jax.random.PRNGKey(0), LAYERS)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *HW, 3))
    for groups, n_groups in ((single_group(len(LAYERS)), 1), (None, 4)):
        counts = {}
        for schedule in ("sync", "overlap"):
            plan = build_stack_plan(HW, LAYERS, 2, 2, groups, schedule=schedule)
            # pools and 1x1 convs contribute no halo; group inputs with a
            # zero-width halo exchange nothing under either schedule
            live = sum(1 for h in plan.group_halos if any(h))
            jaxpr = jax.make_jaxpr(make_tiled_forward(plan, mesh))(params, x)
            counts[schedule] = count_ppermutes(jaxpr)
            per_group = {"sync": 4, "overlap": 2}[schedule]
            assert counts[schedule] == per_group * live, (
                f"{schedule}: {counts[schedule]} ppermutes, want {per_group}x{live}"
            )
        print(f"groups={n_groups}: ppermutes sync={counts['sync']} overlap={counts['overlap']}")
        assert counts["overlap"] * 2 == counts["sync"]
    print("collective count ok (4 -> 2 per group input)")


def check_no_interior_fallback():
    """K=7 conv on 8-row shards: halo reach 3 on each side leaves a 7-wide
    window needing all of an 8-row tile - interior split is empty and the
    executor must fall back to whole-extended-tile compute, still exact."""
    layers = [LayerDef(7, 1, 3, 4, act="leaky")]
    hw = (16, 16)
    params = init_stack_params(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *hw, 3))
    from repro.core.spatial import split_1d

    assert split_1d(8, 3, 3, 7, 1) is not None   # 8 rows: interior exists
    assert split_1d(4, 3, 3, 7, 1) is None       # 4 rows: no interior
    mesh41 = jax.make_mesh((4, 1), ("th", "tw"))
    plan41 = build_stack_plan(hw, layers, 4, 1, schedule="overlap")
    y = jax.jit(make_tiled_forward(plan41, mesh41))(params, x)
    ref = reference_forward(params, x, plan41)
    err = float(jnp.max(jnp.abs(y - ref)))
    print(f"no-interior fallback fwd err={err:.2e}")
    assert err < 1e-4
    print("no-interior fallback ok")


if __name__ == "__main__":
    check_exactness()
    check_collective_count()
    check_no_interior_fallback()
    print("OVERLAP CHECK OK")
